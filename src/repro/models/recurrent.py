"""Recurrent blocks: RG-LRU (RecurrentGemma/Griffin) and xLSTM (mLSTM, sLSTM).

TPU-native formulations:
  * RG-LRU — elementwise linear recurrence ⇒ ``lax.associative_scan`` (log-depth
    parallel prefix, full MXU-free VPU work, O(S·width) memory).
  * mLSTM  — matrix-memory recurrence in *chunkwise-parallel* form: intra-chunk
    attention-like einsums + inter-chunk state carry (exp-gate stabilised in
    log space).  O(S/c) carried states keeps the backward pass feasible —
    a sequential scan would have to stash a (dk×dv) matrix per step.
  * sLSTM  — inherently sequential (hidden feeds gates); ``lax.scan`` over
    time with block-diagonal per-head recurrent weights, input-side gates
    precomputed in parallel.

All three expose a single-token ``*_decode`` path with explicit state, used by
serve_step (bounded state ⇒ these archs run the long_500k cell).
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import _init

Array = jax.Array
Params = Dict[str, Any]

CONV_WIDTH = 4
LRU_C = 8.0          # Griffin's gate sharpness constant
N_GATE_BLOCKS = 4    # block-diagonal gate projections


# ---------------------------------------------------------------------------
# depthwise causal temporal conv (shared by rglru / mlstm branches)
# ---------------------------------------------------------------------------

def init_conv(key, width_channels: int) -> Params:
    return {"w": _init(key, (CONV_WIDTH, width_channels), scale=0.5),
            "b": jnp.zeros((width_channels,), jnp.float32)}


def apply_conv(p: Params, x: Array) -> Array:
    """x (B, S, C) -> causal depthwise conv, width CONV_WIDTH."""
    dt = x.dtype
    pads = jnp.pad(x, ((0, 0), (CONV_WIDTH - 1, 0), (0, 0)))
    out = sum(pads[:, i: i + x.shape[1], :] * p["w"][i].astype(dt)
              for i in range(CONV_WIDTH))
    return out + p["b"].astype(dt)


def apply_conv_decode(p: Params, x_t: Array,
                      cache: Array) -> Tuple[Array, Array]:
    """x_t (B, C), cache (B, CONV_WIDTH-1, C) of previous inputs."""
    dt = x_t.dtype
    win = jnp.concatenate([cache, x_t[:, None, :]], axis=1)  # (B, W, C)
    out = jnp.einsum("bwc,wc->bc", win, p["w"].astype(dt)) + p["b"].astype(dt)
    return out, win[:, 1:, :]


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

def init_rglru(key, cfg: ModelConfig) -> Params:
    d, w = cfg.d_model, cfg.lru_width
    blk = w // N_GATE_BLOCKS
    ks = jax.random.split(key, 7)
    return {
        "w_x": _init(ks[0], (d, w)),             # input branch
        "w_y": _init(ks[1], (d, w)),             # gate branch (gelu)
        "conv": init_conv(ks[2], w),
        "gate_a": _init(ks[3], (N_GATE_BLOCKS, blk, blk)),   # recurrence gate
        "gate_i": _init(ks[4], (N_GATE_BLOCKS, blk, blk)),   # input gate
        "b_a": jnp.zeros((w,), jnp.float32),
        "b_i": jnp.zeros((w,), jnp.float32),
        # Λ init so a = sigmoid(Λ)^c spreads over (0.9, 0.999)
        "lam": jnp.linspace(2.0, 6.0, w).astype(jnp.float32),
        "w_out": _init(ks[5], (w, d)),
    }


def _block_diag_proj(x: Array, w: Array) -> Array:
    """x (..., W) with W = NB*blk; w (NB, blk, blk)."""
    nb, blk, _ = w.shape
    xs = x.reshape(*x.shape[:-1], nb, blk)
    return jnp.einsum("...nb,nbc->...nc", xs,
                      w.astype(x.dtype)).reshape(x.shape)


def _rglru_coeffs(p: Params, u: Array) -> Tuple[Array, Array]:
    """u (B,S,W) post-conv input -> (a_t, b_t) of h_t = a_t h_{t-1} + b_t."""
    r = jax.nn.sigmoid(_block_diag_proj(u, p["gate_a"]).astype(jnp.float32)
                       + p["b_a"])
    i = jax.nn.sigmoid(_block_diag_proj(u, p["gate_i"]).astype(jnp.float32)
                       + p["b_i"])
    log_a = -LRU_C * r * jax.nn.softplus(p["lam"])       # log a_t  (<= 0)
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) in a numerically-safe form
    gate = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = gate * (i * u.astype(jnp.float32))
    return a, b


def apply_rglru(p: Params, x: Array, cfg: ModelConfig) -> Array:
    """Full-sequence RG-LRU block body (pre-norm residual handled by caller)."""
    dt = x.dtype
    y = jax.nn.gelu(x @ p["w_y"].astype(dt))
    u = apply_conv(p["conv"], x @ p["w_x"].astype(dt))
    a, b = _rglru_coeffs(p, u)

    def op(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(op, (a, b), axis=1)
    return ((h.astype(dt) * y) @ p["w_out"].astype(dt))


class RGLRUState(NamedTuple):
    h: Array        # (B, W) fp32
    conv: Array     # (B, CONV_WIDTH-1, W)


def rglru_init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    w = cfg.lru_width
    return RGLRUState(h=jnp.zeros((batch, w), jnp.float32),
                      conv=jnp.zeros((batch, CONV_WIDTH - 1, w), dtype))


def apply_rglru_decode(p: Params, x_t: Array, state: RGLRUState,
                       cfg: ModelConfig) -> Tuple[Array, RGLRUState]:
    """x_t (B, d) -> (out (B, d), new state)."""
    dt = x_t.dtype
    y = jax.nn.gelu(x_t @ p["w_y"].astype(dt))
    u_t, conv = apply_conv_decode(p["conv"], x_t @ p["w_x"].astype(dt),
                                  state.conv)
    a, b = _rglru_coeffs(p, u_t[:, None, :])
    h = a[:, 0] * state.h + b[:, 0]
    out = (h.astype(dt) * y) @ p["w_out"].astype(dt)
    return out, RGLRUState(h=h, conv=conv)


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix memory) — chunkwise parallel
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    dm = int(d * cfg.mlstm_proj_factor)
    ks = jax.random.split(key, 9)
    h = cfg.n_heads
    blk = dm // h
    return {
        "w_up": _init(ks[0], (d, dm)),
        "w_z": _init(ks[1], (d, dm)),            # output-gate branch
        "conv": init_conv(ks[2], dm),
        # q/k/v are block-diagonal per head (xLSTM's BlockDiagonal linear)
        "w_q": _init(ks[3], (h, blk, blk), scale=1.0 / blk ** 0.5),
        "w_k": _init(ks[4], (h, blk, blk), scale=1.0 / blk ** 0.5),
        "w_v": _init(ks[5], (h, blk, blk), scale=1.0 / blk ** 0.5),
        "w_i": _init(ks[6], (dm, cfg.n_heads), scale=0.02),
        "w_f": _init(ks[7], (dm, cfg.n_heads), scale=0.02),
        "b_i": jnp.zeros((cfg.n_heads,), jnp.float32),
        "b_f": 3.0 * jnp.ones((cfg.n_heads,), jnp.float32),  # open forget gates
        "w_down": _init(ks[8], (dm, d)),
    }


class MLSTMState(NamedTuple):
    c: Array        # (B, H, dk, dv) fp32, scale-free (true C = c * exp(m))
    n: Array        # (B, H, dk) fp32
    m: Array        # (B, H) fp32 log-stabiliser
    conv: Array     # (B, CONV_WIDTH-1, dm)


def mlstm_init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    dm = int(cfg.d_model * cfg.mlstm_proj_factor)
    h = cfg.n_heads
    dk = dm // h
    return MLSTMState(
        c=jnp.zeros((batch, h, dk, dk), jnp.float32),
        n=jnp.zeros((batch, h, dk), jnp.float32),
        m=jnp.full((batch, h), -1e30, jnp.float32),
        conv=jnp.zeros((batch, CONV_WIDTH - 1, dm), dtype))


def _head_proj(x: Array, w: Array) -> Array:
    """Block-diagonal per-head projection: (..., dm) × (H, blk, blk) ->
    (..., H, blk)."""
    h, blk, _ = w.shape
    xs = x.reshape(*x.shape[:-1], h, blk)
    return jnp.einsum("...hb,hbc->...hc", xs, w.astype(x.dtype))


def _mlstm_qkv_gates(p: Params, x: Array, cfg: ModelConfig):
    dt = x.dtype
    h = cfg.n_heads
    u = x @ p["w_up"].astype(dt)
    z = x @ p["w_z"].astype(dt)
    c = jax.nn.silu(apply_conv(p["conv"], u))
    b, s, dm = u.shape
    dk = dm // h
    q = _head_proj(c, p["w_q"]).transpose(0, 2, 1, 3)    # (B,H,S,dk)
    k = _head_proj(c, p["w_k"]).transpose(0, 2, 1, 3) / (dk ** 0.5)
    v = _head_proj(u, p["w_v"]).transpose(0, 2, 1, 3)
    log_i = (c.astype(jnp.float32) @ p["w_i"] + p["b_i"]).transpose(0, 2, 1)
    log_f = jax.nn.log_sigmoid(
        (c.astype(jnp.float32) @ p["w_f"] + p["b_f"])).transpose(0, 2, 1)
    return q, k, v, log_i, log_f, z                      # logs: (B,H,S)


def apply_mlstm(p: Params, x: Array, cfg: ModelConfig,
                chunk: Optional[int] = None) -> Array:
    """Full-sequence mLSTM block body, chunkwise-parallel, log-stabilised.

    Chunk size trades carried-state traffic (∝ S/c · dk²) against intra-chunk
    score matrices (∝ S/c · c²) — balanced at c ≈ dk (§Perf iteration log).
    Carried C/N can be bf16 (cfg.mlstm_state_dtype); the log-stabiliser m
    stays f32.
    """
    b, s, d = x.shape
    dt = x.dtype
    nh = cfg.n_heads
    sdt = jnp.dtype(cfg.mlstm_state_dtype)
    q, k, v, log_i, log_f, z = _mlstm_qkv_gates(p, x, cfg)
    dk = q.shape[-1]
    c_len = min(chunk or cfg.mlstm_chunk, s)
    assert s % c_len == 0, (s, c_len)
    nc = s // c_len

    def to_chunks(a, trailing):
        return a.reshape(b, nh, nc, c_len, *trailing).transpose(
            2, 0, 1, 3, *range(4, 4 + len(trailing)))

    qc = to_chunks(q, (dk,))
    kc = to_chunks(k, (dk,))
    vc = to_chunks(v, (dk,))
    lic = to_chunks(log_i, ())
    lfc = to_chunks(log_f, ())

    state0 = (jnp.zeros((b, nh, dk, dk), sdt),
              jnp.zeros((b, nh, dk), sdt),
              jnp.full((b, nh), -1e30, jnp.float32))

    def chunk_step(carry, inp):
        C, N, m = carry
        C = C.astype(jnp.float32)
        N = N.astype(jnp.float32)
        qb, kb, vb, li, lf = inp                       # (B,H,c,·)
        F = jnp.cumsum(lf, axis=-1)                    # (B,H,c) Σ_{l<=i} log f
        # intra logits l_ij = F_i - F_j + li_j  (j <= i)
        lmat = F[..., :, None] - F[..., None, :] + li[..., None, :]
        tri = jnp.tril(jnp.ones((c_len, c_len), bool))
        lmat = jnp.where(tri, lmat, -jnp.inf)
        a_i = lmat.max(-1)                             # (B,H,c)
        e_i = F + m[..., None]                         # inter exponent
        m_i = jnp.maximum(a_i, e_i)
        w_intra = jnp.exp(lmat - m_i[..., None])       # (B,H,c,c)
        w_inter = jnp.exp(e_i - m_i)                   # (B,H,c)
        scores = jnp.einsum("bhik,bhjk->bhij", qb.astype(jnp.float32),
                            kb.astype(jnp.float32)) * w_intra
        h_num = jnp.einsum("bhij,bhjv->bhiv", scores, vb.astype(jnp.float32))
        h_num += w_inter[..., None] * jnp.einsum(
            "bhik,bhkv->bhiv", qb.astype(jnp.float32), C)
        n_vec = jnp.einsum("bhij,bhjk->bhik", w_intra, kb.astype(jnp.float32))
        n_vec += w_inter[..., None] * N[:, :, None, :]
        qn = jnp.einsum("bhik,bhik->bhi", qb.astype(jnp.float32), n_vec)
        denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_i))
        h = h_num / denom[..., None]                   # (B,H,c,dv)
        # state update to end of chunk
        last = F[..., -1:]
        l_end = last - F + li                          # (B,H,c)
        m_new = jnp.maximum(last[..., 0] + m, l_end.max(-1))
        w_end = jnp.exp(l_end - m_new[..., None])
        C_new = (jnp.exp(last[..., 0] + m - m_new)[..., None, None] * C
                 + jnp.einsum("bhj,bhjk,bhjv->bhkv", w_end,
                              kb.astype(jnp.float32), vb.astype(jnp.float32)))
        N_new = (jnp.exp(last[..., 0] + m - m_new)[..., None] * N
                 + jnp.einsum("bhj,bhjk->bhk", w_end, kb.astype(jnp.float32)))
        return (C_new.astype(sdt), N_new.astype(sdt), m_new), h

    _, hs = jax.lax.scan(chunk_step, state0, (qc, kc, vc, lic, lfc))
    h = hs.transpose(1, 2, 0, 3, 4).reshape(b, nh, s, dk)
    h = h.transpose(0, 2, 1, 3).reshape(b, s, nh * dk).astype(dt)
    out = (h * jax.nn.silu(z)) @ p["w_down"].astype(dt)
    return out


def apply_mlstm_decode(p: Params, x_t: Array, state: MLSTMState,
                       cfg: ModelConfig) -> Tuple[Array, MLSTMState]:
    """x_t (B, d) single-token mLSTM step."""
    b, d = x_t.shape
    dt = x_t.dtype
    nh = cfg.n_heads
    dm = int(d * cfg.mlstm_proj_factor)
    u = x_t @ p["w_up"].astype(dt)
    z = x_t @ p["w_z"].astype(dt)
    cin, conv = apply_conv_decode(p["conv"], u, state.conv)
    cin = jax.nn.silu(cin)
    dk = dm // nh
    q = _head_proj(cin, p["w_q"])                        # (B,H,dk)
    k = _head_proj(cin, p["w_k"]) / (dk ** 0.5)
    v = _head_proj(u, p["w_v"])
    log_i = (cin.astype(jnp.float32) @ p["w_i"] + p["b_i"])   # (B,H)
    log_f = jax.nn.log_sigmoid(cin.astype(jnp.float32) @ p["w_f"] + p["b_f"])

    m_new = jnp.maximum(log_f + state.m, log_i)
    w_prev = jnp.exp(log_f + state.m - m_new)
    w_in = jnp.exp(log_i - m_new)
    C = (w_prev[..., None, None] * state.c
         + w_in[..., None, None] * jnp.einsum(
             "bhk,bhv->bhkv", k.astype(jnp.float32), v.astype(jnp.float32)))
    N = w_prev[..., None] * state.n + w_in[..., None] * k.astype(jnp.float32)
    qn = jnp.einsum("bhk,bhk->bh", q.astype(jnp.float32), N)
    denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_new))
    h = jnp.einsum("bhk,bhkv->bhv", q.astype(jnp.float32), C) / denom[..., None]
    h = h.reshape(b, nh * dk).astype(dt)
    out = (h * jax.nn.silu(z)) @ p["w_down"].astype(dt)
    return out, MLSTMState(c=C, n=N, m=m_new, conv=conv)


# ---------------------------------------------------------------------------
# sLSTM — sequential scan, block-diagonal per-head recurrence
# ---------------------------------------------------------------------------

def init_slstm(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    h = cfg.n_heads
    blk = d // h
    ds = int(d * cfg.slstm_proj_factor)
    ks = jax.random.split(key, 7)
    return {
        "w_in": _init(ks[0], (d, 4 * d)),                 # i,f,z,o input paths
        "r": _init(ks[1], (4, h, blk, blk), scale=1.0 / blk ** 0.5),
        "b": jnp.concatenate([jnp.zeros((d,)), 3.0 * jnp.ones((d,)),
                              jnp.zeros((2 * d,))]).astype(jnp.float32),
        "w_ff1": _init(ks[2], (d, ds)),
        "w_ff2": _init(ks[3], (ds, d)),
        "ffn_norm": jnp.ones((d,), jnp.float32),
    }


class SLSTMState(NamedTuple):
    h: Array   # (B, d)
    c: Array   # (B, d)
    n: Array   # (B, d)
    m: Array   # (B, d)


def slstm_init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    d = cfg.d_model
    z = lambda: jnp.zeros((batch, d), jnp.float32)
    return SLSTMState(h=z(), c=z(), n=z(), m=jnp.full((batch, d), -1e30))


def _slstm_cell(p: Params, gates_x: Array, state: SLSTMState,
                nh: int) -> Tuple[Array, SLSTMState]:
    """gates_x (B, 4d) precomputed input-side gates for one step."""
    b, d4 = gates_x.shape
    d = d4 // 4
    blk = d // nh
    h_heads = state.h.reshape(b, nh, blk)
    rec = jnp.einsum("bnk,gnkl->bgnl", h_heads, p["r"]).reshape(b, 4 * d)
    pre = gates_x.astype(jnp.float32) + rec + p["b"]
    gi, gf, gz, go = jnp.split(pre, 4, axis=-1)
    log_f = jax.nn.log_sigmoid(gf)
    m_new = jnp.maximum(log_f + state.m, gi)
    i_p = jnp.exp(gi - m_new)
    f_p = jnp.exp(log_f + state.m - m_new)
    c = f_p * state.c + i_p * jnp.tanh(gz)
    n = f_p * state.n + i_p
    h = jax.nn.sigmoid(go) * c / jnp.maximum(n, 1e-6)
    return h, SLSTMState(h=h, c=c, n=n, m=m_new)


def apply_slstm(p: Params, x: Array, cfg: ModelConfig) -> Array:
    """Full-sequence sLSTM body (sequential scan) + post-FFN."""
    b, s, d = x.shape
    dt = x.dtype
    gates_x = x @ p["w_in"].astype(dt)                    # (B,S,4d) parallel

    def step(state, g_t):
        h, new = _slstm_cell(p, g_t, state, cfg.n_heads)
        return new, h

    _, hs = jax.lax.scan(step, slstm_init_state(cfg, b),
                         gates_x.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2).astype(dt)                  # (B,S,d)
    # post FFN (gelu), pre-normed on h
    ms = jnp.mean(jnp.square(h.astype(jnp.float32)), -1, keepdims=True)
    hn = (h.astype(jnp.float32) * jax.lax.rsqrt(ms + 1e-6)
          * p["ffn_norm"]).astype(dt)
    return h + jax.nn.gelu(hn @ p["w_ff1"].astype(dt)) @ p["w_ff2"].astype(dt)


def apply_slstm_decode(p: Params, x_t: Array, state: SLSTMState,
                       cfg: ModelConfig) -> Tuple[Array, SLSTMState]:
    dt = x_t.dtype
    g = x_t @ p["w_in"].astype(dt)
    h, new_state = _slstm_cell(p, g, state, cfg.n_heads)
    h = h.astype(dt)
    ms = jnp.mean(jnp.square(h.astype(jnp.float32)), -1, keepdims=True)
    hn = (h.astype(jnp.float32) * jax.lax.rsqrt(ms + 1e-6)
          * p["ffn_norm"]).astype(dt)
    out = h + jax.nn.gelu(hn @ p["w_ff1"].astype(dt)) @ p["w_ff2"].astype(dt)
    return out, new_state
