"""End-to-end training driver with fault tolerance.

Runs on anything from 1 CPU device (examples, CI) to the production mesh:
  * sharded init (params materialised directly into their NamedShardings)
  * prefetched host data pipeline (per-host batch slices)
  * async checkpointing every --checkpoint-every steps + WAL-free restart:
    on start, the newest complete generation is restored automatically
  * --simulate-failure N kills the in-process state at step N and restarts
    from the last checkpoint (restart-path regression proof)

Usage (CPU example):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
      --steps 20 --checkpoint-every 5 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointStore
from ..configs import arch_ids, get_config, get_smoke_config
from ..data.synthetic import lm_batches
from ..distributed.sharding import ShardingPolicy
from ..models import (TrainState, abstract_train_state, init_train_state,
                      make_train_step)
from ..models.config import ModelConfig
from ..optim import AdamWConfig, adamw
from ..optim.compression import compress_decompress, init_error_feedback
from .mesh import batch_axes, make_local_mesh


def _flatten_state(state: TrainState) -> dict:
    flat = {}
    leaves = jax.tree_util.tree_flatten_with_path(state)[0]
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "name", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_state(template: TrainState, flat: dict) -> TrainState:
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "name", p)))
                       for p in path)
        leaves.append(jnp.asarray(flat[key]) if key in flat else leaf)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def train(cfg: ModelConfig, *, steps: int, global_batch: int, seq_len: int,
          ckpt_dir: Optional[str] = None, checkpoint_every: int = 0,
          mesh=None, lr: float = 3e-4, log_every: int = 1,
          simulate_failure_at: int = -1, seed: int = 0,
          grad_compress: bool = False) -> dict:
    mesh = mesh or make_local_mesh()
    policy = ShardingPolicy(mesh)
    if global_batch % policy.n_batch_shards == 0 and policy.n_batch_shards > 1:
        cfg = cfg.with_overrides(batch_axes=tuple(batch_axes(mesh)))
    opt_cfg = AdamWConfig(lr=lr, total_steps=max(steps, 2), warmup_steps=min(
        100, steps // 10 + 1))
    if grad_compress:
        # int8 + error feedback at the (DCN) gradient boundary (optim/
        # compression.py): loss -> grads -> compress/decompress -> update
        from ..models.steps import TrainState, make_loss_fn
        loss_fn = make_loss_fn(cfg)

        def step_fn(state_and_ef, batch):
            state, ef = state_and_ef
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state.params, batch)
            grads, ef = compress_decompress(grads, ef)
            params, opt, gnorm = adamw.apply_updates(
                state.params, grads, state.opt, opt_cfg)
            metrics = dict(metrics, loss=loss, grad_norm=gnorm,
                           step=opt.step.astype(jnp.float32))
            return (TrainState(params=params, opt=opt), ef), metrics
    else:
        step_fn = make_train_step(cfg, opt_cfg)

    astate = abstract_train_state(cfg)
    state_sh = policy.sharding_tree(astate)
    store = CheckpointStore(ckpt_dir) if ckpt_dir else None

    with mesh:
        init_jit = jax.jit(lambda k: init_train_state(k, cfg),
                           out_shardings=state_sh)
        state = init_jit(jax.random.PRNGKey(seed))
        start_step = 0
        if store and store.latest() is not None:   # crash recovery
            state = _unflatten_state(state, store.load())
            start_step = int(store.manifest().step)
            print(f"[train] restored generation {store.latest()} "
                  f"at step {start_step}")

        if grad_compress:
            state = (state, init_error_feedback(state.params))
        step_jit = jax.jit(step_fn, donate_argnums=(0,))
        data = lm_batches(cfg.vocab_size, global_batch, seq_len, seed=seed)
        metrics_hist = []
        t0 = time.perf_counter()
        try:
            for step in range(start_step, steps):
                nb = next(data)
                batch = {"tokens": jnp.asarray(nb.tokens),
                         "targets": jnp.asarray(nb.targets),
                         "segment_ids": jnp.asarray(nb.segment_ids)}
                if cfg.is_enc_dec:
                    batch["frames"] = jnp.zeros(
                        (global_batch, seq_len, cfg.d_model),
                        cfg.activation_dtype)
                state, metrics = step_jit(state, batch)
                if simulate_failure_at == step + 1:
                    print(f"[train] >>> simulated failure at step "
                          f"{step + 1} <<<")
                    raise RuntimeError("simulated node failure")
                if (step + 1) % log_every == 0:
                    loss = float(metrics["loss"])
                    metrics_hist.append({"step": step + 1, "loss": loss})
                    print(f"[train] step {step + 1}: loss={loss:.4f} "
                          f"gnorm={float(metrics['grad_norm']):.3f}")
                if store and checkpoint_every and \
                        (step + 1) % checkpoint_every == 0:
                    store.save_async(_flatten_state(
                        state[0] if grad_compress else state), step=step + 1)
        finally:
            if store:
                # flush in-flight async commits even on a crashed run — the
                # IO thread outlives the training step, so a restart must
                # deterministically see every checkpoint that was snapshotted
                store.wait_async()
        if store:
            store.save(_flatten_state(
                state[0] if grad_compress else state), step=steps)
        dt = time.perf_counter() - t0
    return {"metrics": metrics_hist, "seconds": dt,
            "final_loss": metrics_hist[-1]["loss"] if metrics_hist else None}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=arch_ids())
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--simulate-failure-at", type=int, default=-1)
    ap.add_argument("--grad-compress", action="store_true",
                    help="int8 + error-feedback gradient compression")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    try:
        out = train(cfg, steps=args.steps, global_batch=args.global_batch,
                    seq_len=args.seq_len, ckpt_dir=args.ckpt_dir,
                    checkpoint_every=args.checkpoint_every, lr=args.lr,
                    simulate_failure_at=args.simulate_failure_at,
                    grad_compress=args.grad_compress)
        print(f"[train] done in {out['seconds']:.1f}s "
              f"final loss {out['final_loss']}")
    except RuntimeError as e:
        if "simulated" not in str(e):
            raise
        print("[train] restarting after simulated failure ...")
        out = train(cfg, steps=args.steps, global_batch=args.global_batch,
                    seq_len=args.seq_len, ckpt_dir=args.ckpt_dir,
                    checkpoint_every=args.checkpoint_every, lr=args.lr)
        print(f"[train] recovered; final loss {out['final_loss']}")


if __name__ == "__main__":
    main()
