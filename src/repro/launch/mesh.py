"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required because the dry-run overrides the
host platform device count before first jax use.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips).

    Axes: `pod` — DP over DCN (gradient all-reduce only); `data` — FSDP +
    batch DP over ICI; `model` — tensor parallelism over ICI.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    if data * model > n:
        data, model = n, 1
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def batch_axes(mesh) -> tuple:
    """Mesh axes the global batch is sharded over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
