"""Serving driver: a Quantixar Collection behind the request batcher, plus an
optional metadata-filtered query path (the API-layer serving posture).

CPU demo:
  PYTHONPATH=src python -m repro.launch.serve --n 20000 --dim 128 \
      --index hnsw --quant pq --requests 200
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from ..api import Database, KeywordField, VectorField
from ..core.hnsw_build import exact_knn
from ..data.synthetic import gaussian_mixture


def build_database(n: int, dim: int, index: str, quant: str,
                   seed: int = 0):
    """Returns (db, corpus) so callers score recall against exactly the
    vectors that were indexed."""
    db = Database()
    col = db.create_collection(
        name="corpus",
        vector=VectorField(dim=dim, index=index, quantization=quant,
                           builder="bulk"),
        fields=(KeywordField("shard"),))
    corpus = gaussian_mixture(n, dim, seed=seed)
    ids = [f"vec-{i}" for i in range(n)]
    payloads = [{"shard": f"s{i % 8}"} for i in range(n)]
    col.upsert(ids, corpus, payloads)
    return db, corpus


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--index", default="hnsw", choices=["hnsw", "flat"])
    ap.add_argument("--quant", default="none", choices=["none", "pq", "bq"])
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--max-batch", type=int, default=32)
    args = ap.parse_args()

    print(f"[serve] building {args.index}+{args.quant} over {args.n} vectors")
    t0 = time.perf_counter()
    db, corpus = build_database(args.n, args.dim, args.index, args.quant)
    col = db["corpus"]
    col.query(gaussian_mixture(1, args.dim, seed=7)[0]).top_k(1).run()
    print(f"[serve] built in {time.perf_counter() - t0:.1f}s; "
          f"stats={col.stats()}")

    # the Collection's query path IS the batcher path: concurrent submits
    # coalesce into padded engine batches
    queries = gaussian_mixture(args.requests, args.dim, seed=99)
    t0 = time.perf_counter()
    futures = [col.batcher.submit(q, args.k) for q in queries]
    results = [f.result(timeout=60) for f in futures]
    dt = time.perf_counter() - t0

    gt = exact_knn(queries, corpus, args.k, metric="cosine")
    hits = sum(len(set(rows.tolist()) & set(t.tolist()))
               for (_, rows), t in zip(results, gt))
    recall = hits / (len(queries) * args.k)
    print(f"[serve] {args.requests} requests in {dt:.2f}s "
          f"({args.requests / dt:.0f} QPS host-side), "
          f"{col.batcher.batches_served} batches, "
          f"recall@{args.k}={recall:.3f}")

    hits = (col.query(queries[0]).filter(shard="s3").top_k(5).run())
    print(f"[serve] filtered query shard==s3 -> "
          f"{[(h.id, h.payload['shard']) for h in hits]}")
    db.close()


if __name__ == "__main__":
    main()
