"""Serving driver: Quantixar vector search behind a request batcher, plus an
optional LM decode loop (retrieval-augmented generation glue).

CPU demo:
  PYTHONPATH=src python -m repro.launch.serve --n 20000 --dim 128 \
      --index hnsw --quant pq --requests 200
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from ..core import EngineConfig, QuantixarEngine
from ..core.hnsw_build import exact_knn
from ..data.synthetic import gaussian_mixture
from ..serving.batcher import RequestBatcher


def build_engine(n: int, dim: int, index: str, quant: str,
                 builder: str = "bulk", seed: int = 0) -> QuantixarEngine:
    eng = QuantixarEngine(EngineConfig(dim=dim, index=index,
                                       quantization=quant, builder=builder))
    corpus = gaussian_mixture(n, dim, seed=seed)
    meta = [{"shard": int(i % 8)} for i in range(n)]
    eng.add(corpus, meta)
    eng.build(seed=seed)
    return eng


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--index", default="hnsw", choices=["hnsw", "flat"])
    ap.add_argument("--quant", default="none", choices=["none", "pq", "bq"])
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--max-batch", type=int, default=32)
    args = ap.parse_args()

    print(f"[serve] building {args.index}+{args.quant} over {args.n} vectors")
    t0 = time.perf_counter()
    eng = build_engine(args.n, args.dim, args.index, args.quant)
    print(f"[serve] built in {time.perf_counter() - t0:.1f}s; "
          f"stats={eng.stats()}")

    batcher = RequestBatcher(lambda q, k: eng.search(q, k),
                             max_batch=args.max_batch)
    rng = np.random.RandomState(1)
    queries = gaussian_mixture(args.requests, args.dim, seed=99)
    t0 = time.perf_counter()
    futures = [batcher.submit(q, args.k) for q in queries]
    results = [f.result(timeout=60) for f in futures]
    dt = time.perf_counter() - t0
    batcher.close()

    gt = exact_knn(queries, eng.vectors, args.k, metric="cosine")
    hits = sum(len(set(ids.tolist()) & set(t.tolist()))
               for (_, ids), t in zip(results, gt))
    recall = hits / (len(queries) * args.k)
    print(f"[serve] {args.requests} requests in {dt:.2f}s "
          f"({args.requests / dt:.0f} QPS host-side), "
          f"{batcher.batches_served} batches, recall@{args.k}={recall:.3f}")


if __name__ == "__main__":
    main()
