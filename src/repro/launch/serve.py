"""Serving driver: run Quantixar as a real server, or demo/smoke the stack.

Modes:
  * default — embedded demo: build a collection, push requests through the
    serving batcher, report QPS/recall (the pre-service-plane behaviour).
  * `--serve` — start the embedded HTTP server (`repro.serving.http`) on
    --host/--port and serve until interrupted:

        PYTHONPATH=src python -m repro.launch.serve --serve --port 6333 \
            --n 20000 --dim 128 --index hnsw --quant pq

  * `--smoke` — CI smoke: start a server on an ephemeral port, drive it with
    concurrent `QuantixarClient` searches, assert recall, batcher
    coalescing, query-plan parity (coarse-to-fine `.stages()` + `.explain()`
    plan echo, prefetch+RRF fusion, filtered `count`) between embedded and
    wire, and a clean shutdown; exit non-zero on any failure.
"""

from __future__ import annotations

import argparse
import sys
import threading
import time

from ..api import (BatcherConfig, Database, KeywordField, QuantixarClient,
                   VectorField)
from ..core.hnsw_build import HNSWConfig, exact_knn
from ..data.synthetic import gaussian_mixture


def build_database(n: int, dim: int, index: str, quant: str,
                   seed: int = 0, max_batch: int = 32,
                   max_wait_ms: float = 2.0, expansion_width: int = 4,
                   shards: int = 1):
    """Returns (db, corpus) so callers score recall against exactly the
    vectors that were indexed.  `shards > 1` builds a `ShardedCollection`
    (hash-partitioned scatter-gather) instead of a single engine."""
    db = Database()
    col = db.create_collection(
        name="corpus",
        vector=VectorField(dim=dim, index=index, quantization=quant,
                           builder="bulk",
                           hnsw=HNSWConfig(expansion_width=expansion_width)),
        fields=(KeywordField("shard"),),
        batcher=BatcherConfig(max_batch=max_batch, max_wait_ms=max_wait_ms),
        shards=shards)
    corpus = gaussian_mixture(n, dim, seed=seed)
    ids = [f"vec-{i}" for i in range(n)]
    payloads = [{"shard": f"s{i % 8}"} for i in range(n)]
    col.upsert(ids, corpus, payloads)
    return db, corpus


def _recall_of(results, gt, k) -> float:
    hits = sum(len({h.id for h in r} & {f"vec-{j}" for j in t})
               for r, t in zip(results, gt))
    return hits / (len(results) * k)


def run_embedded_demo(args) -> int:
    print(f"[serve] building {args.index}+{args.quant} over {args.n} vectors")
    t0 = time.perf_counter()
    db, corpus = build_database(args.n, args.dim, args.index, args.quant,
                                max_batch=args.max_batch,
                                expansion_width=args.width)
    col = db["corpus"]
    col.query(gaussian_mixture(1, args.dim, seed=7)[0]).top_k(1).run()
    print(f"[serve] built in {time.perf_counter() - t0:.1f}s; "
          f"stats={col.stats()}")

    # the Collection's query path IS the batcher path: concurrent submits
    # coalesce into padded engine batches
    queries = gaussian_mixture(args.requests, args.dim, seed=99)
    t0 = time.perf_counter()
    futures = [col.batcher.submit(q, args.k) for q in queries]
    results = [f.result(timeout=60) for f in futures]
    dt = time.perf_counter() - t0

    gt = exact_knn(queries, corpus, args.k, metric="cosine")
    hits = sum(len(set(rows.tolist()) & set(t.tolist()))
               for (_, rows), t in zip(results, gt))
    recall = hits / (len(queries) * args.k)
    print(f"[serve] {args.requests} requests in {dt:.2f}s "
          f"({args.requests / dt:.0f} QPS host-side), "
          f"{col.batcher.batches_served} batches, "
          f"recall@{args.k}={recall:.3f}")

    hits = (col.query(queries[0]).filter(shard="s3").top_k(5).run())
    print(f"[serve] filtered query shard==s3 -> "
          f"{[(h.id, h.payload['shard']) for h in hits]}")
    db.close()
    return 0


def _start_server(args, port: int):
    from ..serving.http import QuantixarHTTPServer
    from ..serving.service import QuantixarService, ServiceConfig

    db, corpus = build_database(args.n, args.dim, args.index, args.quant,
                                max_batch=args.max_batch,
                                expansion_width=args.width)
    # warm the index so the first client query doesn't pay the build
    db["corpus"].query(gaussian_mixture(1, args.dim, seed=7)[0]).top_k(1).run()
    service = QuantixarService(
        db, ServiceConfig(default_max_batch=args.max_batch))
    server = QuantixarHTTPServer(service, host=args.host, port=port,
                                 verbose=args.verbose)
    return server, corpus


def run_server(args) -> int:
    import signal

    print(f"[serve] building {args.index}+{args.quant} over {args.n} vectors")
    server, _ = _start_server(args, args.port)
    print(f"[serve] listening on {server.url}")
    print(f"[serve] try: curl {server.url}/v1/collections/corpus/stats")
    # SIGTERM (k8s / systemd stop) drains like Ctrl-C
    def _sigterm(signum, frame):
        raise KeyboardInterrupt

    signal.signal(signal.SIGTERM, _sigterm)
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        print("\n[serve] shutting down")
        server.shutdown()
    return 0


def _plan_smoke(server, col, queries, args):
    """Embedded-vs-remote parity of the declarative plan surface: the same
    coarse-to-fine / fused / count queries against the served Database and
    the wire client must agree hit for hit, and `explain()` must echo the
    compiled plan with per-stage counts and timings on both sides."""
    failures = []
    embedded = server.service.db["corpus"]
    k = args.k

    wire_ex = col.query(queries[0]).top_k(k).stages(oversample=4).explain()
    emb_ex = embedded.query(queries[0]).top_k(k).stages(oversample=4) \
        .explain()
    if [h.id for h in wire_ex.hits] != [h.id for h in emb_ex.hits]:
        failures.append("coarse-to-fine wire hits != embedded hits")
    if wire_ex.plan != emb_ex.plan:
        failures.append("explain() plan echo differs embedded vs wire")
    for name, ex in (("wire", wire_ex), ("embedded", emb_ex)):
        shape = [s["stage"] for s in ex.stages]
        if shape != ["ann", "rescore"]:
            failures.append(f"{name} explain stages {shape} != ann+rescore")
        elif not all(s["candidates_out"] > 0 and s["seconds"] >= 0
                     for s in ex.stages):
            failures.append(f"{name} explain missing counts/timings")

    fused, fused_emb = [], []
    for backend, out in ((col, fused), (embedded, fused_emb)):
        q = backend.query(queries[1]).top_k(k)
        for s in range(4):
            q = q.prefetch(shard=f"s{s}")
        out.extend(q.fuse("rrf").run())
    if [h.id for h in fused] != [h.id for h in fused_emb]:
        failures.append("prefetch+RRF wire hits != embedded hits")
    if len(fused) != k:
        failures.append(f"fused query returned {len(fused)}/{k} hits")

    wire_n, embedded_n = col.count(), embedded.count()
    if wire_n != args.n or wire_n != embedded_n:
        failures.append(f"count() mismatch: wire {wire_n} "
                        f"embedded {embedded_n} n {args.n}")
    print(f"[smoke] plan parity: explain={[s['stage'] for s in wire_ex.stages]}"
          f" fused_k={len(fused)} count={wire_n} "
          f"({'ok' if not failures else 'FAILED'})")
    return failures


def run_smoke(args) -> int:
    """Start server → N concurrent client queries → assert recall +
    coalescing + clean shutdown.  The CI serve-smoke job."""
    failures = []
    print(f"[smoke] building {args.index}+{args.quant} over {args.n} vectors")
    server, corpus = _start_server(args, port=0)
    server.start()
    client = QuantixarClient(server.url, timeout=60)
    col = client.collection("corpus")

    queries = gaussian_mixture(args.requests, args.dim, seed=99)
    gt = exact_knn(queries, corpus, args.k, metric="cosine")
    results = [None] * len(queries)

    def worker(i):
        results[i] = col.query(queries[i]).top_k(args.k).run()

    t0 = time.perf_counter()
    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(len(queries))]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    dt = time.perf_counter() - t0

    if any(r is None for r in results):
        failures.append("some client queries never completed")
    else:
        recall = _recall_of(results, gt, args.k)
        stats = col.stats()
        batches = stats["serving_batches_served"]
        served = stats["serving_requests_served"]
        print(f"[smoke] {len(queries)} wire queries in {dt:.2f}s "
              f"({len(queries) / dt:.0f} QPS), recall@{args.k}={recall:.3f}, "
              f"{batches} batches for {served} batched requests")
        if recall < args.min_recall:
            failures.append(f"recall {recall:.3f} < {args.min_recall}")
        if served < len(queries):
            failures.append(f"only {served} requests took the batcher path")
        if batches >= served and served > 1:
            failures.append(
                f"no coalescing: {batches} batches for {served} requests")

    failures += _plan_smoke(server, col, queries, args)

    try:
        server.shutdown()
    except Exception as exc:                  # noqa: BLE001
        failures.append(f"shutdown failed: {exc}")
    for f in failures:
        print(f"[smoke] FAIL: {f}")
    print(f"[smoke] {'FAILED' if failures else 'PASSED'}")
    return 1 if failures else 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--index", default="hnsw", choices=["hnsw", "flat", "ivf"])
    ap.add_argument("--quant", default="none", choices=["none", "pq", "bq"])
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--width", type=int, default=4,
                    help="wide-beam expansion width (HNSW serving default)")
    ap.add_argument("--serve", action="store_true",
                    help="run the HTTP server until interrupted")
    ap.add_argument("--smoke", action="store_true",
                    help="server + concurrent client queries + assertions")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=6333)
    ap.add_argument("--min-recall", type=float, default=0.7)
    ap.add_argument("--verbose", action="store_true",
                    help="per-request HTTP logging")
    args = ap.parse_args()

    if args.smoke:
        return run_smoke(args)
    if args.serve:
        return run_server(args)
    return run_embedded_demo(args)


if __name__ == "__main__":
    sys.exit(main())
