"""Multi-pod dry-run: lower + compile every (architecture × shape × mesh)
cell on virtual TPU meshes and extract memory/cost/collective analyses.

MUST be the very first lines — before any other import, including repro.* —
because jax locks the device count at first initialisation:
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

# ---------------------------------------------------------------------------

import argparse      # noqa: E402
import json          # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs import arch_ids, get_config  # noqa: E402
from repro.configs.quantixar_db import CONFIG as DB_CONFIG  # noqa: E402
from repro.distributed.sharding import ShardingPolicy  # noqa: E402
from repro.distributed import search as dsearch  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.mesh import mesh_axis_sizes as mesh_axis_sizes_local  # noqa: E402
from repro.launch import specs as SP  # noqa: E402
from repro.models import (abstract_train_state, make_serve_step,  # noqa: E402
                          make_train_step)
from repro.models.model import abstract_params, forward  # noqa: E402
from repro.optim import AdamWConfig  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", ".."))
from benchmarks import hlo_cost as HC  # noqa: E402
from benchmarks import roofline as RL  # noqa: E402

OUT_DIR = os.environ.get(
    "QUANTIXAR_DRYRUN_DIR",
    os.path.join(os.path.dirname(__file__), "..", "..", "..",
                 "experiments", "dryrun"))

DB_MODES = ("flat", "pq", "bq",               # paper-faithful 2D baseline
            "flat-rows", "pq-rows", "bq-rows")  # §Perf rows-mode optimized


def _mesh(multi_pod: bool):
    return make_production_mesh(multi_pod=multi_pod)


def _mesh_tag(multi_pod: bool) -> str:
    return "pod2x16x16" if multi_pod else "pod16x16"


# ---------------------------------------------------------------------------
# cell builders: return (jitted_fn, example_args) for .lower(*args)
# ---------------------------------------------------------------------------

def build_lm_cell(arch: str, shape: str, mesh, variant: str = "base"):
    cfg = get_config(arch)
    cell = SP.SHAPES[shape]
    opt = variant == "opt"
    # §Perf iteration 3 (xlstm): blocked-per-head matrix-state recurrences
    # resist 16-way TP (every layout couples a state einsum across shards);
    # a ~2 GB-param model is better served folding `model` into DP
    dp_only = (opt and cfg.family == "ssm"
               and cell.global_batch % mesh.devices.size == 0)
    policy = ShardingPolicy(mesh, shard_cache_seq=opt,
                            head_proj_model_only=opt, dp_only=dp_only)
    # pin activation batch dim to the mesh batch axes (skip batch=1 cells)
    if cell.global_batch % policy.n_batch_shards == 0:
        cfg = cfg.with_overrides(batch_axes=tuple(policy.batch_axes))
    if opt:
        # §Perf beyond-baseline package: uniform-position decode (DUS cache
        # update, no cache gathers), extent attention, mLSTM chunk ≈ dk with
        # bf16 carried state. Gather-based MoE dispatch only in the
        # tiny-expert regime: einsum dispatch overhead ≈ g/(3·d_ff) of the
        # expert flops — 67% for granite (d_ff=512), 2.4% for mixtral
        # (d_ff=14336), where gather's scatter-heavy backward costs more
        # than it saves (measured 0.47x — see EXPERIMENTS.md §Perf 3.1b).
        dispatch = ("gather" if cfg.moe_experts and cfg.d_ff < cfg.d_model
                    else cfg.moe_dispatch)
        # Megatron-SP measured per-arch (§Perf 5): 2.8x on qwen2, 1.7x on
        # recurrentgemma, 1.3x on starcoder2, 1.2x on chameleon — but WORSE
        # on qk-norm/MHA/MoE/enc-dec archs (resharding churn around their
        # extra per-layer ops). Layout choices are per-arch, by measurement.
        sp_archs = {"qwen2-1.5b", "starcoder2-15b", "recurrentgemma-9b",
                    "chameleon-34b"}
        cfg = cfg.with_overrides(
            decode_pos_mode="uniform", moe_dispatch=dispatch,
            attn_schedule="extent", bf16_weight_gather=True,
            sequence_parallel=(cell.kind == "train" and not dp_only
                               and arch in sp_archs
                               and cell.seq_len % 16 == 0),
            mlstm_chunk=1024, mlstm_state_dtype="bfloat16")

    if cell.kind == "train":
        step = make_train_step(cfg, AdamWConfig(total_steps=10_000))
        astate = abstract_train_state(cfg)
        abatch = SP.lm_train_specs(cfg, cell)
        state_sh = policy.sharding_tree(astate)
        batch_sh = policy.batch_sharding_tree(abatch)
        jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                         out_shardings=(state_sh, None),
                         donate_argnums=(0,))
        return jitted, (astate, abatch), policy

    if cell.kind == "prefill":
        def prefill_step(params, batch):
            logits, _ = forward(params, batch, cfg)
            return logits[:, -1, :]              # next-token logits only

        aparams = abstract_params(cfg)
        abatch = SP.lm_train_specs(cfg, cell)
        abatch.pop("targets")
        abatch.pop("segment_ids")
        params_sh = policy.sharding_tree(aparams)
        batch_sh = policy.batch_sharding_tree(abatch)
        jitted = jax.jit(prefill_step, in_shardings=(params_sh, batch_sh))
        return jitted, (aparams, abatch), policy

    if cell.kind == "decode":
        serve = make_serve_step(cfg)
        aparams = abstract_params(cfg)
        atokens, astate = SP.lm_decode_specs(cfg, cell)
        params_sh = policy.sharding_tree(aparams)
        state_sh = policy.serve_sharding_tree(astate)
        tok_sh = policy.batch_sharding_tree(atokens)
        jitted = jax.jit(serve, in_shardings=(params_sh, state_sh, tok_sh),
                         out_shardings=(tok_sh, state_sh),
                         donate_argnums=(1,))
        return jitted, (aparams, astate, atokens), policy

    raise ValueError(cell.kind)


def build_db_cell(mode: str, mesh):
    k = DB_CONFIG.k
    base, _, layout = mode.partition("-")
    layout = layout or "dims"          # bare names = paper-faithful baseline
    rows_mult = mesh.devices.size if layout == "rows" else (
        mesh.devices.size // mesh_axis_sizes_local(mesh).get("model", 1))
    if base == "flat":
        fn = dsearch.make_flat_search(mesh, k=k, metric=DB_CONFIG.metric,
                                      dim=DB_CONFIG.dim, mode=layout)
        sp = SP.db_specs(DB_CONFIG, "flat", row_multiple=rows_mult)
        return fn, (sp["corpus"], sp["queries"]), None
    if base == "pq":
        fn = dsearch.make_pq_search(mesh, k=k, m_subspaces=DB_CONFIG.pq_m,
                                    mode=layout)
        sp = SP.db_specs(DB_CONFIG, "pq", row_multiple=rows_mult)
        return fn, (sp["codes"], sp["lut"]), None
    if base == "bq":
        fn = dsearch.make_hamming_search(mesh, k=k,
                                         words=DB_CONFIG.bq_bits // 32,
                                         mode=layout)
        sp = SP.db_specs(DB_CONFIG, "bq", row_multiple=rows_mult)
        return fn, (sp["codes"], sp["q_codes"]), None
    raise ValueError(mode)


# ---------------------------------------------------------------------------
# model-FLOPs (the "useful work" numerator for §Roofline)
# ---------------------------------------------------------------------------

def model_flops_for(arch: str, shape: str) -> float:
    cfg = get_config(arch)
    cell = SP.SHAPES[shape]
    n_active = cfg.active_param_count()
    tokens = cell.global_batch * cell.seq_len
    if cell.kind == "train":
        return RL.train_model_flops(n_active, tokens)
    if cell.kind == "prefill":
        return 2.0 * n_active * tokens
    return RL.decode_model_flops(n_active, cell.global_batch)


def db_model_flops(mode: str) -> float:
    n, q, d = DB_CONFIG.n_vectors, DB_CONFIG.query_batch, DB_CONFIG.dim
    base = mode.partition("-")[0]
    if base == "flat":
        return 2.0 * q * n * d
    if base == "pq":
        return 1.0 * q * n * DB_CONFIG.pq_m
    return 3.0 * q * n * (DB_CONFIG.bq_bits // 32)


# ---------------------------------------------------------------------------
# runner
# ---------------------------------------------------------------------------

SAVE_HLO = bool(os.environ.get("QUANTIXAR_SAVE_HLO", ""))


def run_cell(name: str, builder, model_flops: float, mesh, multi_pod: bool,
             out_dir: str):
    tag = _mesh_tag(multi_pod)
    os.makedirs(os.path.join(out_dir, tag), exist_ok=True)
    path = os.path.join(out_dir, tag, f"{name}.json")
    rec = {"cell": name, "mesh": tag, "chips": mesh.devices.size}
    t0 = time.perf_counter()
    try:
        jitted, args, policy = builder(mesh)
        with mesh:
            lowered = jitted.lower(*args)
            t1 = time.perf_counter()
            compiled = lowered.compile()
            t2 = time.perf_counter()
        if SAVE_HLO:
            import gzip
            with gzip.open(os.path.join(out_dir, tag, f"{name}.hlo.gz"),
                           "wt") as f:
                f.write(compiled.as_text())
        mem = compiled.memory_analysis()
        cost = HC.xla_cost_dict(compiled)
        # trip-count-aware HLO analysis (XLA-CPU cost_analysis counts loop
        # bodies once — see benchmarks/hlo_cost.py)
        hc = HC.analyze(compiled.as_text())
        rl = RL.Roofline(flops=hc.flops, hbm_bytes=hc.bytes_fused,
                         collective_bytes=hc.collective_total,
                         model_flops=model_flops, chips=mesh.devices.size)
        rec.update({
            "ok": True,
            "lower_s": round(t1 - t0, 2),
            "compile_s": round(t2 - t1, 2),
            "flops_per_device": hc.flops,
            "bytes_per_device": hc.bytes_fused,
            "bytes_naive_per_device": hc.bytes_naive,
            "collective_bytes_per_device": hc.collective_total,
            "collectives": hc.coll_summary(),
            "collective_bytes_by_kind": hc.coll_bytes,
            "collective_counts": hc.coll_count,
            "loops": hc.loops[:12],
            "xla_cost_analysis_flops": float(cost.get("flops", 0.0)),
            "xla_bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            "model_flops": model_flops,
            "memory_analysis": _mem_dict(mem),
            "roofline": rl.row(),
        })
        if policy is not None:
            rec["replicated_params"] = policy.replicated_report()[:20]
    except Exception as e:  # record failures — they are bugs to fix
        rec.update({"ok": False, "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:]})
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    status = "OK " if rec.get("ok") else "FAIL"
    extra = ""
    if rec.get("ok"):
        r = rec["roofline"]
        extra = (f"compile={rec['compile_s']}s "
                 f"bottleneck={r['bottleneck']} step={r['roofline_step_s']}s "
                 f"mem/dev={rec['memory_analysis'].get('argument_size_gb', '?')}GB")
    else:
        extra = rec["error"][:200]
    print(f"[{status}] {tag} {name}: {extra}", flush=True)
    return rec


def _mem_dict(mem) -> dict:
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        try:
            out[attr] = int(getattr(mem, attr))
        except Exception:
            pass
    if "argument_size_in_bytes" in out:
        out["argument_size_gb"] = round(out["argument_size_in_bytes"] / 2**30, 3)
    if "temp_size_in_bytes" in out:
        out["temp_size_gb"] = round(out["temp_size_in_bytes"] / 2**30, 3)
    total = sum(out.get(k, 0) for k in ("argument_size_in_bytes",
                                        "output_size_in_bytes",
                                        "temp_size_in_bytes"))
    out["total_gb"] = round(total / 2**30, 3)
    out["fits_16gb_hbm"] = total < 16 * 2**30
    return out


def iter_cells(archs, shapes, db: bool):
    for arch in archs:
        cfg = get_config(arch)
        for shape in shapes:
            ok, why = SP.cell_supported(cfg, shape)
            yield arch, shape, ok, why
    if db:
        for mode in DB_MODES:
            yield "quantixar-db", mode, True, ""


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    help="arch id, comma list, or 'all'")
    ap.add_argument("--shape", default="all",
                    help="shape name, comma list, or 'all'")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--db", action="store_true",
                    help="also run quantixar-db cells")
    ap.add_argument("--db-only", action="store_true")
    ap.add_argument("--variant", default="base", choices=["base", "opt"],
                    help="opt = §Perf beyond-baseline package; records get "
                         "an __opt suffix")
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    archs = arch_ids() if args.arch == "all" else args.arch.split(",")
    shapes = list(SP.SHAPES) if args.shape == "all" else args.shape.split(",")
    if args.db_only:
        archs, shapes = [], []

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    if args.list:
        for arch, shape, ok, why in iter_cells(archs, shapes,
                                               args.db or args.db_only):
            print(f"{arch:24s} {shape:12s} {'run' if ok else why}")
        return

    n_fail = 0
    for multi_pod in meshes:
        mesh = _mesh(multi_pod)
        for arch, shape, ok, why in iter_cells(archs, shapes,
                                               args.db or args.db_only):
            suffix = "__opt" if args.variant == "opt" else ""
            name = f"{arch}__{shape}{suffix}"
            if not ok:
                tag = _mesh_tag(multi_pod)
                os.makedirs(os.path.join(args.out, tag), exist_ok=True)
                with open(os.path.join(args.out, tag, f"{name}.json"),
                          "w") as f:
                    json.dump({"cell": name, "mesh": tag, "ok": True,
                               "skipped": why}, f, indent=1)
                print(f"[SKIP] {tag} {name}: {why}", flush=True)
                continue
            if arch == "quantixar-db":
                rec = run_cell(name, lambda m, mode=shape: build_db_cell(
                    mode, m), db_model_flops(shape), mesh, multi_pod,
                    args.out)
            else:
                rec = run_cell(
                    name,
                    lambda m, a=arch, s=shape, v=args.variant:
                        build_lm_cell(a, s, m, variant=v),
                    model_flops_for(arch, shape), mesh, multi_pod, args.out)
            n_fail += 0 if rec.get("ok") else 1
    print(f"\ndry-run complete; failures: {n_fail}")
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
