"""Input ShapeDtypeStruct specs for every (architecture × shape) dry-run cell.

Shapes (assignment):
  train_4k     seq 4096,   global_batch 256   -> train_step
  prefill_32k  seq 32768,  global_batch 32    -> prefill (forward) step
  decode_32k   seq 32768 cache, global_batch 128, 1 new token -> serve_step
  long_500k    seq 524288 cache, global_batch 1 -> serve_step
               (sub-quadratic archs only; skips recorded in DESIGN.md §5)

Plus the paper's own workload (quantixar-db): sharded flat / PQ / BQ scans.
No array is ever allocated here — everything is jax.ShapeDtypeStruct
(weak-type-correct, shardable).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from ..models.model import abstract_decode_state

S = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str             # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


def cell_supported(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    """Assignment skip rules (skips are recorded, not silently dropped)."""
    if shape == "long_500k" and not cfg.supports_long_context:
        return False, ("skipped: pure full-attention arch — 512k-token dense "
                       "KV cache is the quadratic regime long_500k excludes "
                       "(DESIGN.md §5)")
    return True, ""


def lm_train_specs(cfg: ModelConfig, cell: ShapeCell) -> Dict[str, Any]:
    b, s = cell.global_batch, cell.seq_len
    specs = {
        "tokens": S((b, s), jnp.int32),
        "targets": S((b, s), jnp.int32),
        "segment_ids": S((b, s), jnp.int32),
    }
    if cfg.is_enc_dec:
        # audio frontend stub: precomputed frame embeddings
        specs["frames"] = S((b, s, cfg.d_model), cfg.activation_dtype)
    return specs


def lm_decode_specs(cfg: ModelConfig, cell: ShapeCell):
    """(tokens, abstract decode state) for serve_step."""
    b = cell.global_batch
    cache_len = cell.seq_len
    cross_len = cell.seq_len if cfg.is_enc_dec else 0
    state = abstract_decode_state(cfg, b, cache_len, with_cross_len=cross_len)
    return S((b, 1), jnp.int32), state


# ---------------------------------------------------------------------------
# quantixar-db cells (the paper's own workload)
# ---------------------------------------------------------------------------

def db_specs(db_cfg, mode: str, row_multiple: int = 1) -> Dict[str, Any]:
    """row_multiple: round the corpus up to a shard multiple (the engine pads
    with +inf rows on ingest — shard_map requires even row partitions)."""
    n, d, q = db_cfg.n_vectors, db_cfg.dim, db_cfg.query_batch
    n = -(-n // row_multiple) * row_multiple
    if mode == "flat":
        return {"corpus": S((n, d), jnp.float32),
                "queries": S((q, d), jnp.float32)}
    if mode == "pq":
        return {"codes": S((n, db_cfg.pq_m), jnp.uint8),
                "lut": S((q, db_cfg.pq_m, db_cfg.pq_k), jnp.float32)}
    if mode == "bq":
        w = db_cfg.bq_bits // 32
        return {"codes": S((n, w), jnp.uint32),
                "q_codes": S((q, w), jnp.uint32)}
    raise ValueError(mode)
