"""Sharded host data pipeline with background prefetch.

Production posture (1000+ nodes): every host independently materialises only
its own shard of the global batch (`host_slice`), so ingestion bandwidth
scales linearly with hosts and a straggling host never blocks another's input
pipeline — the step barrier is the only synchronisation point.  A bounded
background prefetch queue hides host→device transfer behind compute
(double-buffering).
"""

from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Iterator, Optional

import jax
import numpy as np


def host_slice(global_batch: int, num_hosts: int, host_id: int) -> slice:
    """Contiguous rows of the global batch owned by `host_id`."""
    if global_batch % num_hosts != 0:
        raise ValueError(f"global_batch {global_batch} % hosts {num_hosts} != 0")
    per = global_batch // num_hosts
    return slice(host_id * per, (host_id + 1) * per)


class Prefetcher:
    """Bounded background prefetch of an iterator (depth-N double buffering)."""

    _SENTINEL = object()

    def __init__(self, it: Iterator[Any], depth: int = 2,
                 transform: Optional[Callable[[Any], Any]] = None):
        self._q: "queue.Queue[Any]" = queue.Queue(maxsize=depth)
        self._transform = transform
        self._err: Optional[BaseException] = None

        def run():
            try:
                for item in it:
                    if self._transform is not None:
                        item = self._transform(item)
                    self._q.put(item)
            except BaseException as e:  # surfaced on next()
                self._err = e
            finally:
                self._q.put(self._SENTINEL)

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._SENTINEL:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item


def device_put_batches(it: Iterator[Any], sharding=None,
                       depth: int = 2) -> Iterator[Any]:
    """Prefetch + device_put each pytree of numpy arrays."""

    def put(batch):
        return jax.tree_util.tree_map(
            lambda a: jax.device_put(np.asarray(a), sharding)
            if sharding is not None else jax.device_put(np.asarray(a)),
            batch)

    return Prefetcher(it, depth=depth, transform=put)
