"""Synthetic corpora statistically matched to the paper's datasets.

The ANN-Benchmark downloads (Fashion-MNIST-784, SIFT-128) are unavailable
offline; these generators reproduce the *structure that matters to the
algorithms under test*:

  * fashion_mnist_like — 784-d, 10 class clusters with shared low-rank
    structure, non-negative pixel-ish range, heavy intra-class correlation —
    what drives HNSW's easy recall on Fashion-MNIST.
  * sift_like — 128-d local-gradient-histogram statistics: non-negative,
    heavy-tailed (exponential magnitudes), block-sparse, L2-comparable —
    the harder, flatter distance distribution of SIFT.
  * gaussian_mixture — generic clustered corpus for quantizer tests.
  * token streams — Zipf-distributed LM batches for the architecture cells.

All generators are deterministic in (seed, shape).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    dim: int
    metric: str


FASHION_MNIST = DatasetSpec("fashion-mnist-784", 784, "l2")
SIFT = DatasetSpec("sift-128", 128, "l2")


def gaussian_mixture(n: int, dim: int, n_clusters: int = 32,
                     scale: float = 0.25, seed: int = 0,
                     return_labels: bool = False):
    """Clustered unit-norm-ish corpus — the generic ANN workload."""
    rng = np.random.RandomState(seed)
    centers = rng.randn(n_clusters, dim).astype(np.float32)
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    labels = rng.randint(0, n_clusters, size=n)
    x = centers[labels] + scale * rng.randn(n, dim).astype(np.float32)
    if return_labels:
        return x.astype(np.float32), labels
    return x.astype(np.float32)


def fashion_mnist_like(n: int, seed: int = 0) -> np.ndarray:
    """784-d, 10 classes, low-rank class templates + pixel noise, clipped ≥ 0."""
    rng = np.random.RandomState(seed)
    rank = 24
    basis = rng.randn(rank, 784).astype(np.float32)
    class_w = rng.randn(10, rank).astype(np.float32) * 2.0
    labels = rng.randint(0, 10, size=n)
    w = class_w[labels] + 0.5 * rng.randn(n, rank).astype(np.float32)
    x = w @ basis + 0.35 * rng.randn(n, 784).astype(np.float32)
    x = np.maximum(x + 1.5, 0.0)                  # pixel-like non-negativity
    return (x * 32.0).astype(np.float32)          # ~[0, 255] range


def sift_like(n: int, seed: int = 0) -> np.ndarray:
    """128-d gradient-histogram statistics: non-negative, heavy-tailed,
    4x4 spatial blocks of 8 orientation bins with within-block correlation."""
    rng = np.random.RandomState(seed)
    # block energies: log-normal per 16 spatial cells
    energy = np.exp(0.8 * rng.randn(n, 16, 1)).astype(np.float32)
    orient = rng.exponential(1.0, size=(n, 16, 8)).astype(np.float32)
    x = (energy * orient).reshape(n, 128)
    # SIFT-style clipping + renorm at 512 scale
    norm = np.linalg.norm(x, axis=1, keepdims=True)
    x = np.minimum(x / np.maximum(norm, 1e-9), 0.2)
    norm2 = np.linalg.norm(x, axis=1, keepdims=True)
    return (512.0 * x / np.maximum(norm2, 1e-9)).astype(np.float32)


def make_corpus(spec: DatasetSpec, n: int, seed: int = 0) -> np.ndarray:
    if spec.name.startswith("fashion"):
        return fashion_mnist_like(n, seed)
    if spec.name.startswith("sift"):
        return sift_like(n, seed)
    return gaussian_mixture(n, spec.dim, seed=seed)


# ---------------------------------------------------------------------------
# LM token streams (architecture training cells)
# ---------------------------------------------------------------------------

def zipf_tokens(rng: np.random.RandomState, shape: Tuple[int, ...],
                vocab: int, alpha: float = 1.1) -> np.ndarray:
    """Zipf-distributed token ids in [0, vocab) — realistic rank-frequency."""
    # inverse-CDF sampling on a truncated zipf
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = ranks ** (-alpha)
    probs /= probs.sum()
    cdf = np.cumsum(probs)
    u = rng.random_sample(shape)
    return np.searchsorted(cdf, u).astype(np.int32)


@dataclasses.dataclass
class TokenBatch:
    tokens: np.ndarray      # (B, S) int32
    targets: np.ndarray     # (B, S) int32 (next-token shifted)
    segment_ids: np.ndarray  # (B, S) int32 (1 = real, 0 = pad)


def lm_batches(vocab: int, batch: int, seq_len: int, seed: int = 0,
               max_vocab_sample: int = 50_000) -> Iterator[TokenBatch]:
    """Infinite deterministic stream of LM batches.

    Sampling cost is kept O(min(vocab, max_vocab_sample)) — huge embedding
    tables don't need every id exercised to train/benchmark.
    """
    rng = np.random.RandomState(seed)
    v = min(vocab, max_vocab_sample)
    while True:
        toks = zipf_tokens(rng, (batch, seq_len + 1), v)
        yield TokenBatch(tokens=toks[:, :-1],
                         targets=toks[:, 1:],
                         segment_ids=np.ones((batch, seq_len), np.int32))
