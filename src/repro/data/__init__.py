"""Data pipeline: synthetic corpora + sharded host loading."""

from .pipeline import Prefetcher, device_put_batches, host_slice
from .synthetic import (FASHION_MNIST, SIFT, DatasetSpec, fashion_mnist_like,
                        gaussian_mixture, lm_batches, make_corpus, sift_like,
                        zipf_tokens)
